"""Parameter tables for the node-aware max-rate communication model.

The paper (Bienz/Gropp/Olson, EuroMPI'18) splits the classic postal/max-rate
parameters along two axes:

* **protocol** — short / eager / rendezvous, selected by message size;
* **locality** — intra-socket / intra-node(cross-socket) / inter-node.

and adds two scalar penalties:

* ``gamma`` — receive-queue search cost per queue element (T_q = gamma * n^2)
* ``delta`` — per-byte network-link contention penalty (T_c = delta * ell)

``CommParams`` stores these as dense ``[n_locality, n_protocol]`` tables so the
model functions in :mod:`repro.core.models` can vectorize over messages.

The locality axis is an open *rate table*, not a fixed three-class enum: the
heterogeneous-node presets (Lockhart et al. 2022) extend it with device
classes — intra-device, cross-device (NVLink / Infinity Fabric), host<->device
copy (``h2d``), and two *network paths* per inter-node pair (``host_staged``
vs ``device_direct`` GPU-NIC) — plus a per-node NIC/rail count ``n_rails``
that the max-rate mechanism divides active senders across.  Model code never
hard-codes class indices; it indexes the table by the per-message ``loc``
array and resolves named classes via :meth:`CommParams.class_index`.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

INF = float("inf")

# Protocol indices (message-size regimes).
SHORT, EAGER, REND = 0, 1, 2
PROTOCOL_NAMES = ("short", "eager", "rend")

# Default size thresholds (bytes).  Blue Waters' CrayMPI switches
# eager->rendezvous around 8 KiB; "short" rides in the envelope.
DEFAULT_SHORT_MAX = 512
DEFAULT_EAGER_MAX = 8192


@dataclasses.dataclass(frozen=True)
class CommParams:
    """Locality- and protocol-split postal/max-rate parameters.

    Attributes
    ----------
    locality_names: names of locality classes, ordered "closest" first.
    alpha:  [L, P] per-message latency (seconds).
    Rb:     [L, P] per-process transport rate (bytes/second); beta = 1/Rb.
    RN:     [L, P] node injection-bandwidth cap (bytes/second); ``inf`` where
            injection is not a bottleneck (e.g. intra-node traffic).
    gamma:  queue-search cost per element (seconds).
    delta:  per-byte contention penalty on the hottest link (seconds/byte).
    short_max / eager_max: protocol size thresholds in bytes.
    network_locality: index of the first locality class that traverses the
            network (used by contention/injection logic).
    n_rails: NICs (injection rails) per node.  The max-rate mechanism divides
            a node's active senders across its rails — ``ceil(ppn / n_rails)``
            processes contend per NIC — so a multi-rail node saturates ``RN``
            later than a single-NIC node with the same per-rail cap.
    """

    locality_names: tuple[str, ...]
    alpha: np.ndarray
    Rb: np.ndarray
    RN: np.ndarray
    gamma: float
    delta: float
    short_max: int = DEFAULT_SHORT_MAX
    eager_max: int = DEFAULT_EAGER_MAX
    network_locality: int = 2
    n_rails: int = 1

    @property
    def n_locality(self) -> int:
        return len(self.locality_names)

    def protocol_of(self, size) -> np.ndarray:
        """Vectorized protocol classification by message size (bytes)."""
        size = np.asarray(size)
        return np.where(size <= self.short_max, SHORT,
                        np.where(size <= self.eager_max, EAGER, REND)).astype(np.int32)

    def class_index(self, name: str) -> int:
        """Index of locality class ``name`` in this table's rate rows.

        Strategy rewrites that override a phase's class (staged copies, the
        ``host_staged`` network path) resolve indices through this instead of
        hard-coding table positions; a table without the class raises a
        ``ValueError`` naming the classes it does have.
        """
        try:
            return self.locality_names.index(name)
        except ValueError:
            raise ValueError(
                f"{name!r} is not a locality class of this parameter table; "
                f"available classes: {self.locality_names}") from None

    def has_class(self, name: str) -> bool:
        """Whether ``name`` is a locality class of this rate table."""
        return name in self.locality_names

    def replace(self, **kw) -> "CommParams":
        """A copy of this table with the named fields replaced (``kw`` maps
        field name to new value, as :func:`dataclasses.replace`)."""
        return dataclasses.replace(self, **kw)


def _tbl(rows: Sequence[Sequence[float]]) -> np.ndarray:
    """rows indexed [protocol][locality] -> array [locality, protocol]."""
    return np.asarray(rows, dtype=np.float64).T


def blue_waters() -> CommParams:
    """Table 1 of the paper: node-aware max-rate parameters on Blue Waters.

    Localities: 0=intra-socket, 1=intra-node (cross socket), 2=inter-node.
    """
    alpha = _tbl([
        # intra-socket, intra-node, inter-node
        [4.4e-07, 8.3e-07, 2.3e-06],   # short
        [5.3e-07, 1.2e-06, 7.0e-06],   # eager
        [1.7e-06, 2.5e-06, 3.0e-06],   # rendezvous
    ])
    Rb = _tbl([
        [2.2e09, 4.8e08, 1.3e09],
        [3.2e09, 9.6e08, 7.5e08],
        [6.2e09, 6.2e09, 2.9e09],
    ])
    RN = _tbl([
        [INF, INF, INF],
        [INF, INF, INF],
        [INF, INF, 6.6e09],            # injection limit only for rendezvous
    ])
    return CommParams(
        locality_names=("intra_socket", "intra_node", "inter_node"),
        alpha=alpha, Rb=Rb, RN=RN,
        gamma=8.4e-09,                  # Eq. (4)
        delta=1.0e-10,                  # Eq. (6)
        network_locality=2,
    )


def tpu_v5e() -> CommParams:
    """TPU v5e adaptation of the node-aware parameter table.

    Localities: 0=intra-host (4 chips/tray), 1=intra-pod (ICI torus),
    2=inter-pod (DCN).  These are *design parameters*: there is no hardware in
    this container to calibrate against, so values are set from public specs
    (ICI ~50 GB/s/link, 4 links/chip; DCN ~25 GB/s/host) with latency floors
    typical of XLA transfer launch.  The model only needs internally-consistent
    parameters to rank layouts; absolute accuracy is calibrated on-hardware via
    :mod:`repro.core.fitting` exactly as the paper does with ping-pongs.
    """
    alpha = _tbl([
        # intra-host, intra-pod(ICI), inter-pod(DCN)
        [8.0e-07, 1.0e-06, 1.0e-05],   # small
        [9.0e-07, 1.5e-06, 2.0e-05],   # medium
        [1.2e-06, 2.0e-06, 5.0e-05],   # large
    ])
    Rb = _tbl([
        [2.0e10, 1.0e10, 1.0e09],
        [4.0e10, 3.0e10, 3.0e09],
        [5.0e10, 4.5e10, 6.25e09],
    ])
    # Injection cap: 4 ICI links/chip x ~45 GB/s effective; DCN per-chip share
    # of a 25 GB/s host NIC.
    RN = _tbl([
        [INF, 1.8e11, 2.5e10],
        [INF, 1.8e11, 2.5e10],
        [INF, 1.8e11, 2.5e10],
    ])
    return CommParams(
        locality_names=("intra_host", "intra_pod", "inter_pod"),
        alpha=alpha, Rb=Rb, RN=RN,
        gamma=1.0e-08,                  # per-outstanding-DMA match/dispatch cost
        delta=5.0e-11,                  # ICI link contention penalty
        short_max=DEFAULT_SHORT_MAX,
        eager_max=DEFAULT_EAGER_MAX,
        network_locality=1,             # ICI already traverses torus links
    )


# -- heterogeneous (GPU) nodes ----------------------------------------------
#
# Locality classes of the heterogeneous presets, "closest" first.  The first
# three never traverse the network; ``h2d`` (host<->device copy) is only ever
# assigned by an explicit class override (a copy is a staging decision, not a
# pair geometry), and the two network classes are the two *paths* an
# inter-node pair can take: staged through host memory and the host NIC, or
# GPU-NIC direct (GPUDirect / NIC-per-GCD).  ``MachineSpec.locality``
# classifies cross-node pairs with the machine's configured default path;
# the GPU-aware strategy rewrites pit the two paths against each other.
HETERO_LOCALITIES = ("intra_device", "cross_device", "h2d",
                     "host_staged", "device_direct")
HETERO_NETWORK_LOCALITY = 3        # host_staged and device_direct are net


def lassen() -> CommParams:
    """Lassen-like fat GPU node: 4 V100-class devices, dual-rail host NICs.

    Design parameters in the spirit of Lockhart et al. 2022 (no such hardware
    exists in this container; absolute values are calibrated on-hardware via
    :mod:`repro.core.fitting`, exactly as the paper does with ping-pongs).
    The load-bearing *shape*: the device-direct path has no copy overhead but
    a low rendezvous rate (early GPUDirect RDMA reads), while the host-staged
    path pays h2d copies yet rides the full dual-rail host NIC bandwidth —
    which is what makes the two GPU-aware strategies cross over as traffic
    grows.
    """
    alpha = _tbl([
        # intra_device, cross_device, h2d,   host_staged, device_direct
        [3.0e-06, 4.0e-06, 6.0e-06, 1.5e-06, 2.5e-06],   # short
        [3.5e-06, 5.0e-06, 6.5e-06, 3.0e-06, 4.5e-06],   # eager
        [5.0e-06, 7.0e-06, 8.0e-06, 5.0e-06, 9.0e-06],   # rendezvous
    ])
    Rb = _tbl([
        [2.0e11, 3.0e10, 1.0e10, 3.0e09, 3.0e09],
        [4.0e11, 3.5e10, 1.1e10, 8.0e09, 5.0e09],
        [6.0e11, 4.0e10, 1.2e10, 1.25e10, 4.5e09],
    ])
    RN = _tbl([
        [INF, INF, INF, INF, INF],
        [INF, INF, INF, INF, INF],
        [INF, INF, INF, 1.25e10, 6.5e09],  # per-rail / per-NIC injection cap
    ])
    return CommParams(
        locality_names=HETERO_LOCALITIES,
        alpha=alpha, Rb=Rb, RN=RN,
        gamma=1.2e-08,                  # GPU-aware MPI match cost
        delta=1.0e-10,
        network_locality=HETERO_NETWORK_LOCALITY,
        n_rails=2,                      # dual-rail IB per node
    )


def frontier() -> CommParams:
    """Frontier-like 8-GCD node: a NIC per GCD pair, device-direct native.

    The mirror image of :func:`lassen`: Slingshot NICs hang off the GPUs, so
    the device-direct path gets the full per-NIC rate across 4 rails, while
    staging through host memory costs an extra copy *and* a slower host send
    path.  Design parameters (see :func:`lassen` on calibration).
    """
    alpha = _tbl([
        # intra_device, cross_device, h2d,   host_staged, device_direct
        [2.5e-06, 3.5e-06, 5.0e-06, 2.0e-06, 1.8e-06],   # short
        [3.0e-06, 4.5e-06, 5.5e-06, 4.0e-06, 2.6e-06],   # eager
        [4.0e-06, 6.0e-06, 7.0e-06, 7.0e-06, 4.0e-06],   # rendezvous
    ])
    Rb = _tbl([
        [3.0e11, 4.0e10, 2.4e10, 3.0e09, 8.0e09],
        [5.0e11, 4.5e10, 2.6e10, 6.0e09, 1.6e10],
        [8.0e11, 5.0e10, 2.8e10, 1.0e10, 2.2e10],
    ])
    RN = _tbl([
        [INF, INF, INF, INF, INF],
        [INF, INF, INF, INF, INF],
        [INF, INF, INF, 1.0e10, 2.5e10],   # per-NIC injection cap
    ])
    return CommParams(
        locality_names=HETERO_LOCALITIES,
        alpha=alpha, Rb=Rb, RN=RN,
        gamma=1.0e-08,
        delta=8.0e-11,
        network_locality=HETERO_NETWORK_LOCALITY,
        n_rails=4,                      # 4 Slingshot NICs per node
    )


# Hardware roofline constants for TPU v5e (per chip).
V5E_PEAK_FLOPS_BF16 = 197e12     # FLOP/s
V5E_HBM_BW = 819e9               # bytes/s
V5E_ICI_LINK_BW = 50e9           # bytes/s per link
V5E_ICI_LINKS_PER_CHIP = 4       # 2-D torus: +-x, +-y
V5E_DCN_BW_PER_HOST = 25e9       # bytes/s
V5E_CHIPS_PER_HOST = 4
V5E_HBM_PER_CHIP = 16 * 1024**3  # bytes
