"""Parameter tables for the node-aware max-rate communication model.

The paper (Bienz/Gropp/Olson, EuroMPI'18) splits the classic postal/max-rate
parameters along two axes:

* **protocol** — short / eager / rendezvous, selected by message size;
* **locality** — intra-socket / intra-node(cross-socket) / inter-node.

and adds two scalar penalties:

* ``gamma`` — receive-queue search cost per queue element (T_q = gamma * n^2)
* ``delta`` — per-byte network-link contention penalty (T_c = delta * ell)

``CommParams`` stores these as dense ``[n_locality, n_protocol]`` tables so the
model functions in :mod:`repro.core.models` can vectorize over messages.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

INF = float("inf")

# Protocol indices (message-size regimes).
SHORT, EAGER, REND = 0, 1, 2
PROTOCOL_NAMES = ("short", "eager", "rend")

# Default size thresholds (bytes).  Blue Waters' CrayMPI switches
# eager->rendezvous around 8 KiB; "short" rides in the envelope.
DEFAULT_SHORT_MAX = 512
DEFAULT_EAGER_MAX = 8192


@dataclasses.dataclass(frozen=True)
class CommParams:
    """Locality- and protocol-split postal/max-rate parameters.

    Attributes
    ----------
    locality_names: names of locality classes, ordered "closest" first.
    alpha:  [L, P] per-message latency (seconds).
    Rb:     [L, P] per-process transport rate (bytes/second); beta = 1/Rb.
    RN:     [L, P] node injection-bandwidth cap (bytes/second); ``inf`` where
            injection is not a bottleneck (e.g. intra-node traffic).
    gamma:  queue-search cost per element (seconds).
    delta:  per-byte contention penalty on the hottest link (seconds/byte).
    short_max / eager_max: protocol size thresholds in bytes.
    network_locality: index of the first locality class that traverses the
            network (used by contention/injection logic).
    """

    locality_names: tuple[str, ...]
    alpha: np.ndarray
    Rb: np.ndarray
    RN: np.ndarray
    gamma: float
    delta: float
    short_max: int = DEFAULT_SHORT_MAX
    eager_max: int = DEFAULT_EAGER_MAX
    network_locality: int = 2

    @property
    def n_locality(self) -> int:
        return len(self.locality_names)

    def protocol_of(self, size) -> np.ndarray:
        """Vectorized protocol classification by message size (bytes)."""
        size = np.asarray(size)
        return np.where(size <= self.short_max, SHORT,
                        np.where(size <= self.eager_max, EAGER, REND)).astype(np.int32)

    def replace(self, **kw) -> "CommParams":
        return dataclasses.replace(self, **kw)


def _tbl(rows: Sequence[Sequence[float]]) -> np.ndarray:
    """rows indexed [protocol][locality] -> array [locality, protocol]."""
    return np.asarray(rows, dtype=np.float64).T


def blue_waters() -> CommParams:
    """Table 1 of the paper: node-aware max-rate parameters on Blue Waters.

    Localities: 0=intra-socket, 1=intra-node (cross socket), 2=inter-node.
    """
    alpha = _tbl([
        # intra-socket, intra-node, inter-node
        [4.4e-07, 8.3e-07, 2.3e-06],   # short
        [5.3e-07, 1.2e-06, 7.0e-06],   # eager
        [1.7e-06, 2.5e-06, 3.0e-06],   # rendezvous
    ])
    Rb = _tbl([
        [2.2e09, 4.8e08, 1.3e09],
        [3.2e09, 9.6e08, 7.5e08],
        [6.2e09, 6.2e09, 2.9e09],
    ])
    RN = _tbl([
        [INF, INF, INF],
        [INF, INF, INF],
        [INF, INF, 6.6e09],            # injection limit only for rendezvous
    ])
    return CommParams(
        locality_names=("intra_socket", "intra_node", "inter_node"),
        alpha=alpha, Rb=Rb, RN=RN,
        gamma=8.4e-09,                  # Eq. (4)
        delta=1.0e-10,                  # Eq. (6)
        network_locality=2,
    )


def tpu_v5e() -> CommParams:
    """TPU v5e adaptation of the node-aware parameter table.

    Localities: 0=intra-host (4 chips/tray), 1=intra-pod (ICI torus),
    2=inter-pod (DCN).  These are *design parameters*: there is no hardware in
    this container to calibrate against, so values are set from public specs
    (ICI ~50 GB/s/link, 4 links/chip; DCN ~25 GB/s/host) with latency floors
    typical of XLA transfer launch.  The model only needs internally-consistent
    parameters to rank layouts; absolute accuracy is calibrated on-hardware via
    :mod:`repro.core.fitting` exactly as the paper does with ping-pongs.
    """
    alpha = _tbl([
        # intra-host, intra-pod(ICI), inter-pod(DCN)
        [8.0e-07, 1.0e-06, 1.0e-05],   # small
        [9.0e-07, 1.5e-06, 2.0e-05],   # medium
        [1.2e-06, 2.0e-06, 5.0e-05],   # large
    ])
    Rb = _tbl([
        [2.0e10, 1.0e10, 1.0e09],
        [4.0e10, 3.0e10, 3.0e09],
        [5.0e10, 4.5e10, 6.25e09],
    ])
    # Injection cap: 4 ICI links/chip x ~45 GB/s effective; DCN per-chip share
    # of a 25 GB/s host NIC.
    RN = _tbl([
        [INF, 1.8e11, 2.5e10],
        [INF, 1.8e11, 2.5e10],
        [INF, 1.8e11, 2.5e10],
    ])
    return CommParams(
        locality_names=("intra_host", "intra_pod", "inter_pod"),
        alpha=alpha, Rb=Rb, RN=RN,
        gamma=1.0e-08,                  # per-outstanding-DMA match/dispatch cost
        delta=5.0e-11,                  # ICI link contention penalty
        short_max=DEFAULT_SHORT_MAX,
        eager_max=DEFAULT_EAGER_MAX,
        network_locality=1,             # ICI already traverses torus links
    )


# Hardware roofline constants for TPU v5e (per chip).
V5E_PEAK_FLOPS_BF16 = 197e12     # FLOP/s
V5E_HBM_BW = 819e9               # bytes/s
V5E_ICI_LINK_BW = 50e9           # bytes/s per link
V5E_ICI_LINKS_PER_CHIP = 4       # 2-D torus: +-x, +-y
V5E_DCN_BW_PER_HOST = 25e9       # bytes/s
V5E_CHIPS_PER_HOST = 4
V5E_HBM_PER_CHIP = 16 * 1024**3  # bytes
