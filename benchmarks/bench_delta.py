"""Delta-vs-rebuild benchmarks: incremental re-pricing as a search engine.

Two rows:

``delta_local_search_64``
    A 64-move boundary-shift local search on the elasticity-like operator
    (the paper's application class) priced two ways over the *identical*
    candidate sequence: through the :class:`repro.comm.DeltaStack` /
    :func:`repro.sparse.spmv_comm_pattern_delta` incremental path, and by
    replaying the recorded candidate partitions with full per-candidate
    reconstruction (fresh ``spmv_comm_pattern`` + ``CommPhase.build`` +
    pricing).  Replaying — rather than running a second independent search —
    pins both sides to the same candidates by construction, so an ulp-level
    cost tie can never fork the accept decisions and flake the comparison.
    Every candidate's modeled cost is asserted allclose between the two
    pricers before timing counts; ``derived`` is the rebuild/delta speedup
    (the ``perf_smoke`` CI gate fails if it ever drops below 1.0 —
    incremental must never lose).  The rebuild timing is generous to
    rebuild: it excludes all search bookkeeping, pure pricing only.

``delta_amg_optimize``
    The new-scenario row: run :func:`repro.sparse.optimize_partition` on
    every level of a Poisson AMG hierarchy and report the end-to-end wall
    time with the summed modeled cost reduction as ``derived`` — the
    optimization trace the quickstart example prints per level.

``delta_service_qps``
    Sustained service throughput: the full ``DEFAULT_SCENARIOS`` registry
    priced through :class:`repro.serve.StrategyService` warm (every query
    a fingerprint cache hit) vs cold (a fresh service re-running the
    sweep per query).  Reported as warm us/query with ``derived`` the
    cold/warm speedup; warm verdicts are asserted bit-identical to the
    cold ones before timing counts, and the ``perf_smoke`` gate fails if
    the cached path ever loses to the rebuild.

Run directly for a CSV::

    PYTHONPATH=src python -m benchmarks.bench_delta
"""
from __future__ import annotations

import time

import numpy as np


def _search_kwargs():
    from repro.sparse import elasticity_like_3d
    return elasticity_like_3d(12), dict(n_procs=512, moves=64, seed=0,
                                        level="contention")


def bench_delta_local_search():
    from repro.core.models import phase_cost_many
    from repro.net import blue_waters_machine
    from repro.sparse import RowPartition, optimize_partition, \
        spmv_comm_pattern

    machine = blue_waters_machine((4, 2, 2))
    A, kw = _search_kwargs()

    def run_delta():
        return optimize_partition(A, machine, **kw)

    def replay_rebuild(moves):
        """Rebuild-per-candidate over the recorded candidate partitions."""
        out = []
        for mv in moves:
            if np.isnan(mv.cost):            # infeasible: never priced
                out.append(float("nan"))
                continue
            phase = spmv_comm_pattern(A, RowPartition(mv.starts)) \
                .bind(machine)
            out.append(phase_cost_many([phase], level=kw["level"])[0].total)
        return out

    # correctness first: rebuild pricing of the identical candidates must
    # agree with what the delta pricer recorded
    res = run_delta()
    costs_d = np.asarray([m.cost for m in res.moves])
    costs_r = np.asarray(replay_rebuild(res.moves))
    assert np.array_equal(np.isnan(costs_d), np.isnan(costs_r))
    assert np.allclose(np.nan_to_num(costs_d), np.nan_to_num(costs_r),
                       rtol=1e-9), "delta pricer drifted from rebuild"

    best_d = best_r = float("inf")
    for _ in range(3):
        t0 = time.perf_counter()
        res = run_delta()
        best_d = min(best_d, time.perf_counter() - t0)
        t0 = time.perf_counter()
        replay_rebuild(res.moves)
        best_r = min(best_r, time.perf_counter() - t0)
    return [("delta_local_search_64", best_d * 1e6, best_r / best_d)]


def bench_delta_amg_optimize():
    from repro.net import blue_waters_machine
    from repro.sparse import build_hierarchy, poisson_3d, optimize_partition

    machine = blue_waters_machine((4, 2, 2))
    levels = build_hierarchy(poisson_3d(14), theta=0.25)
    t0 = time.perf_counter()
    before = after = 0.0
    for lvl in levels:
        if lvl.A.n_rows < 4:        # too coarse for two non-empty blocks
            continue
        n_procs = min(256, lvl.A.n_rows // 2)
        res = optimize_partition(lvl.A, machine, n_procs=n_procs,
                                 moves=48, seed=0)
        before += res.initial_cost
        after += res.cost
    us = (time.perf_counter() - t0) * 1e6
    reduction = 0.0 if before <= 0 else 1.0 - after / before
    return [("delta_amg_optimize", us, reduction)]


def bench_service_qps():
    from repro.net import lassen_machine
    from repro.serve import StrategyService
    from repro.workloads.registry import DEFAULT_SCENARIOS, scenario_patterns

    machine = lassen_machine((2, 2, 2))
    pats = [p for sc in DEFAULT_SCENARIOS for _, p in scenario_patterns(sc)]

    # correctness first: warm (cached) verdicts must be bit-identical to
    # the cold sweep that populated them
    svc = StrategyService(machine, backend="numpy")
    cold_res = svc.query_many(pats)
    warm_res = svc.query_many(pats)
    assert all(r.cached for r in warm_res), "warm pass missed the cache"
    for c, w in zip(cold_res, warm_res):
        assert w.verdict.model == c.verdict.model, "cached verdict drifted"
        assert w.verdict.sim == c.verdict.sim, "cached verdict drifted"

    best_cold = best_warm = float("inf")
    for _ in range(3):
        t0 = time.perf_counter()
        StrategyService(machine, backend="numpy").query_many(pats)
        best_cold = min(best_cold, time.perf_counter() - t0)
        t0 = time.perf_counter()
        svc.query_many(pats)
        best_warm = min(best_warm, time.perf_counter() - t0)
    return [("delta_service_qps", best_warm / len(pats) * 1e6,
             best_cold / best_warm)]


ALL_BENCHES = [bench_delta_local_search, bench_delta_amg_optimize,
               bench_service_qps]


if __name__ == "__main__":
    print("name,us_per_call,derived")
    for bench in ALL_BENCHES:
        for name, us, derived in bench():
            print(f"{name},{us:.1f},{derived:.6g}", flush=True)
