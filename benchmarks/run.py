"""Benchmark harness: one benchmark per paper table/figure + kernels +
roofline.  Prints ``name,us_per_call,derived`` CSV."""
from __future__ import annotations

import sys
import traceback


def main() -> None:
    from . import (bench_paper, bench_kernels, bench_roofline, bench_delta,
                   bench_stack_backends, bench_llm_workloads, bench_faults,
                   bench_exec)
    print("name,us_per_call,derived")
    failures = 0
    for mod in (bench_paper, bench_kernels, bench_roofline, bench_delta,
                bench_stack_backends, bench_llm_workloads, bench_faults,
                bench_exec):
        for bench in mod.ALL_BENCHES:
            try:
                for (name, us, derived) in bench():
                    print(f"{name},{us:.1f},{derived:.6g}", flush=True)
            except Exception:  # noqa: BLE001
                failures += 1
                print(f"{bench.__name__},nan,nan  # FAILED", flush=True)
                traceback.print_exc(file=sys.stderr)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
