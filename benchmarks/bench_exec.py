"""Execution-layer benchmarks: measured vs predicted strategy orderings.

Rows (``name,us_per_call,derived``):

``exec_model_agreement``
    The calibrated-model loop closed end to end, numpy-only: fit parameter
    tables for the Lassen- and Frontier-like presets from recorded
    noiseless sweeps (:mod:`repro.exec.calibrate` — the model side never
    peeks at ground truth), predict every GPU-strategy winner on the
    crossover pattern set with the *fitted* table, and judge against the
    simulator's ground-truth verdict.  ``derived`` is the fraction of
    (machine, count) cases where the calibrated model picks the
    simulator's winner.

``exec_agreement_crossover``
    The direct-vs-aggregated crossover cases specifically: the small end
    (``device_direct`` wins under the simulator), the large end
    (``host_staged`` wins) and the flip itself on the Lassen-like preset.
    ``derived`` is 1.0 only when the sweep really crosses over AND the
    calibrated model calls every one of those cases — the gated row in
    ``perf_smoke`` (a model that misses the crossover is not predicting,
    it is guessing).

The jax rows run the lowered schedules on a forced 8-device host mesh in a
subprocess (absent without jax — optional in the gate):

``exec_measured_<strategy>``
    Median wall-clock of the lowered schedule on the host mesh
    (``us_per_call``) with the calibrated model's predicted cost in
    seconds as ``derived`` — the measured-vs-predicted table, one row per
    strategy on the host-scale Lassen preset.  Bit-identity vs the
    reference executor is asserted inside before timing.

``exec_wallclock_agreement``
    Pairwise ordering agreement between the measured wall-clock ranking
    and the calibrated model's predicted ranking on the host mesh.
    Reported, not gated: the host CPU mesh is a different machine from
    the preset the model describes — the *simulator* rows above are the
    apples-to-apples agreement gate.

``exec_launch_overhead``
    Median wall-clock of launching the empty ``standard`` schedule (all
    launch, no payload); ``derived`` is that overhead as a fraction of the
    measured ``standard`` schedule time.

``exec_standard_vs_naive``
    The greedy edge-colored ``standard`` schedule vs the naive
    one-``ppermute``-per-message lowering of the same exchange
    (``coloring='per_message'``), identical delivered payloads asserted.
    ``derived`` is naive/colored — gated >= 1.0 in ``perf_smoke``: fusing
    messages into permutation rounds must never lose to the per-message
    loop.

Run directly for the CSV::

    PYTHONPATH=src python -m benchmarks.bench_exec
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import time

import numpy as np

COUNTS = (8, 32, 128, 512, 2048)


def _timed(fn):
    t0 = time.perf_counter()
    out = fn()
    return out, (time.perf_counter() - t0) * 1e6


def _crossover_phases(machine):
    from repro.comm import CommPhase
    out = []
    for n in COUNTS:
        rng = np.random.default_rng(42)
        P = machine.n_procs
        src = rng.integers(0, P, n)
        dst = (src + rng.integers(1, P, n)) % P
        size = rng.integers(256, 8192, n).astype(float)
        out.append(CommPhase.build(machine, src, dst, size, n_procs=P))
    return out


def bench_exec_agreement():
    """Calibrated-model vs simulator strategy ordering (numpy-only)."""
    from repro.comm.strategies import GPU_STRATEGIES, best_strategy_many
    from repro.exec import calibrate, record_sweeps
    from repro.net import frontier_machine, lassen_machine

    def run():
        agrees, lassen_verdicts = [], []
        for mk, dims in ((lassen_machine, (2, 2, 2)),
                         (frontier_machine, (2, 2, 1))):
            machine = mk(dims)
            fitted = calibrate(record_sweeps(machine), machine.params).params
            verdicts = best_strategy_many(_crossover_phases(machine),
                                          strategies=GPU_STRATEGIES,
                                          seed=0, params=fitted)
            agrees += [v.agree for v in verdicts]
            if machine.name == "lassen":
                lassen_verdicts = verdicts
        # the crossover cases: small end (direct), large end (staged) and
        # the first staged count on the Lassen-like sweep
        winners = [v.sim_winner for v in lassen_verdicts]
        staged = [i for i, w in enumerate(winners) if w == "host_staged"]
        crossed = (winners[0] == "device_direct" and staged
                   and winners[-1] == "host_staged")
        cases = ([0, staged[0], len(winners) - 1] if crossed else [])
        crossover_ok = bool(crossed) and all(lassen_verdicts[i].agree
                                             for i in cases)
        return float(np.mean(agrees)), float(crossover_ok)

    (agreement, crossover_ok), us = _timed(run)
    return [("exec_model_agreement", us, agreement),
            ("exec_agreement_crossover", us, crossover_ok)]


_MESH_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import numpy as np
from repro.comm.phase import CommPhase
from repro.comm.strategies import strategies_for
from repro.exec import (build_schedule, build_executor, calibrate,
                        launch_overhead, lassen_8, predicted_costs,
                        record_sweeps, run_reference, time_schedule)

m = lassen_8()
rng = np.random.default_rng(42)
n = 96
src = rng.integers(0, 8, n)
dst = (src + rng.integers(1, 8, n)) % 8
size = rng.integers(256, 8192, n).astype(float)
phase = CommPhase.build(m, src, dst, size, n_procs=8)

fitted = calibrate(record_sweeps(m), m.params).params
predicted = predicted_costs(phase, params=fitted)

measured = {}
for strat in strategies_for(m):
    sched = build_schedule(phase, strat)
    got = build_executor(sched)()
    assert np.array_equal(got, run_reference(sched)), strat
    measured[strat] = time_schedule(sched, reps=5, warmup=2).median_s

overhead = launch_overhead(phase, reps=5, warmup=2)

# naive per-message lowering of the all-to-all standard exchange
colored = build_schedule(phase, "standard")
naive = build_schedule(phase, "standard", coloring="per_message")
assert np.array_equal(run_reference(colored), run_reference(naive))
t_colored = time_schedule(colored, reps=5, warmup=2).median_s
t_naive = time_schedule(naive, reps=5, warmup=2).median_s

print(json.dumps({"measured": measured, "predicted": predicted,
                  "overhead": overhead, "t_colored": t_colored,
                  "t_naive": t_naive,
                  "rounds": [colored.n_rounds, naive.n_rounds]}))
"""


def bench_exec_schedules():
    """Lowered schedules timed on the forced 8-device host mesh (jax)."""
    try:
        import jax  # noqa: F401
    except ImportError:
        return []
    from repro.exec import pairwise_agreement

    env = dict(os.environ)
    out = subprocess.run([sys.executable, "-c", _MESH_SCRIPT], env=env,
                         capture_output=True, text=True, timeout=600)
    if out.returncode != 0:
        raise RuntimeError(f"mesh benchmark failed:\n{out.stderr[-2000:]}")
    r = json.loads(out.stdout.strip().splitlines()[-1])

    rows = []
    for strat, med in r["measured"].items():
        rows.append((f"exec_measured_{strat}", med * 1e6,
                     r["predicted"][strat]))
    std = r["measured"]["standard"]
    rows.append(("exec_wallclock_agreement", 0.0,
                 pairwise_agreement(r["measured"], r["predicted"])))
    rows.append(("exec_launch_overhead", r["overhead"] * 1e6,
                 r["overhead"] / std if std > 0 else 0.0))
    rows.append(("exec_standard_vs_naive", r["t_colored"] * 1e6,
                 r["t_naive"] / r["t_colored"]))
    return rows


ALL_BENCHES = [bench_exec_agreement, bench_exec_schedules]


if __name__ == "__main__":
    print("name,us_per_call,derived")
    for bench in ALL_BENCHES:
        for name, us, derived in bench():
            print(f"{name},{us:.1f},{derived:.6g}")
