"""CI perf smoke: the PhaseStack sweep path must never lose to the loop.

Checks the ``stack_*`` rows of :mod:`benchmarks.bench_kernels` (stacked
sweep vs per-phase loop on the AMG hierarchy x partition scan, bit-identity
asserted inside the bench) and fails if any stacked path is slower than its
per-phase loop path.  The threshold is 1.0x — deliberately far below the
typical speedups — so CI-runner throttling noise cannot flake the gate while
a real regression (the stack falling back to the loop, a cache being lost,
a reduction going quadratic) still trips it.

Usage::

    python -m benchmarks.perf_smoke [bench.csv]

With a CSV argument (the ``benchmarks.run`` output, as in CI) the gate is
applied to its ``stack_*`` rows without re-running the workload; without one
the benchmark is executed directly (local development).
"""
from __future__ import annotations

import sys

STACK_ROWS = ("stack_model_ladder", "stack_simulate", "stack_best_strategy")


def _rows_from_csv(path: str):
    rows = []
    with open(path) as f:
        for line in f:
            parts = line.strip().split(",")
            if parts and parts[0] in STACK_ROWS:
                rows.append((parts[0], float(parts[1]), float(parts[2])))
    if {name for name, _, _ in rows} != set(STACK_ROWS):
        raise SystemExit(f"{path} is missing stack_* rows — did "
                         "benchmarks.run fail before bench_phase_stack?")
    return rows


def main() -> None:
    if len(sys.argv) > 1:
        rows = _rows_from_csv(sys.argv[1])
    else:
        from .bench_kernels import bench_phase_stack
        rows = bench_phase_stack()
    failed = False
    for name, us, speedup in rows:
        status = "ok" if speedup >= 1.0 else "SLOWER THAN LOOP"
        print(f"{name}: {us:.0f} us/sweep, {speedup:.2f}x vs loop  [{status}]")
        failed |= speedup < 1.0
    if failed:
        sys.exit(1)


if __name__ == "__main__":
    main()
