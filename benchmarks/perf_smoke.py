"""CI perf smoke: the fast paths must never lose to their reference paths.

Two gates, both thresholded at 1.0x — deliberately far below the typical
speedups, so CI-runner throttling noise cannot flake the gate while a real
regression still trips it:

* the ``stack_*`` rows of :mod:`benchmarks.bench_kernels` (stacked sweep vs
  per-phase loop on the AMG hierarchy x partition scan, bit-identity
  asserted inside the bench) — the PhaseStack sweep path must never be
  slower than the loop;
* the ``delta_local_search_64`` row of :mod:`benchmarks.bench_delta`
  (incremental re-pricing vs rebuild-per-candidate on the same 64-move
  local search, candidate costs asserted allclose inside the bench) — the
  DeltaStack path must never be slower than a full rebuild.

Usage::

    python -m benchmarks.perf_smoke [bench.csv]

With a CSV argument (the ``benchmarks.run`` output, as in CI) the gates are
applied to its rows without re-running the workloads; without one the
benchmarks are executed directly (local development).
"""
from __future__ import annotations

import sys

STACK_ROWS = ("stack_model_ladder", "stack_simulate", "stack_best_strategy")
DELTA_ROWS = ("delta_local_search_64",)
GATED_ROWS = STACK_ROWS + DELTA_ROWS


def _rows_from_csv(path: str):
    rows = []
    with open(path) as f:
        for line in f:
            parts = line.strip().split(",")
            if parts and parts[0] in GATED_ROWS:
                rows.append((parts[0], float(parts[1]), float(parts[2])))
    missing = set(GATED_ROWS) - {name for name, _, _ in rows}
    if missing:
        raise SystemExit(f"{path} is missing gated rows {sorted(missing)} — "
                         "did benchmarks.run fail before producing them?")
    return rows


def main() -> None:
    if len(sys.argv) > 1:
        rows = _rows_from_csv(sys.argv[1])
    else:
        from .bench_delta import bench_delta_local_search
        from .bench_kernels import bench_phase_stack
        rows = bench_phase_stack() + bench_delta_local_search()
    failed = False
    for name, us, speedup in rows:
        # stack rows report us per sweep evaluation; the delta row reports
        # us for the whole 64-move search
        ref, unit = (("loop", "us/sweep") if name in STACK_ROWS
                     else ("rebuild", "us/search"))
        status = "ok" if speedup >= 1.0 else f"SLOWER THAN {ref.upper()}"
        print(f"{name}: {us:.0f} {unit}, {speedup:.2f}x vs {ref}  "
              f"[{status}]")
        failed |= speedup < 1.0
    if failed:
        sys.exit(1)


if __name__ == "__main__":
    main()
