"""CI perf smoke: the fast paths must never lose to their reference paths.

Gates, each with a per-row threshold deliberately below the typical
speedup, so CI-runner throttling noise cannot flake the gate while a real
regression still trips it:

* the ``stack_*`` rows of :mod:`benchmarks.bench_kernels` (stacked sweep vs
  per-phase loop on the AMG hierarchy x partition scan, bit-identity
  asserted inside the bench) — the PhaseStack sweep path must never be
  slower than the loop (>= 1.0x);
* the ``delta_local_search_64`` row of :mod:`benchmarks.bench_delta`
  (incremental re-pricing vs rebuild-per-candidate on the same 64-move
  local search, candidate costs asserted allclose inside the bench) — the
  DeltaStack path must never be slower than a full rebuild (>= 1.0x);
* the ``delta_service_qps`` row of the same bench (the full scenario
  registry through a warm :class:`repro.serve.StrategyService` vs a cold
  rebuild per query, cached verdicts asserted bit-identical inside the
  bench) — the fingerprint cache must never lose to re-running the sweep
  (>= 1.0x; in practice the hit path is orders of magnitude ahead);
* the ``stack_auto_*`` rows of :mod:`benchmarks.bench_stack_backends` —
  the autotuned backend default must never pick a backend slower than
  numpy.  On a host whose crossover probe reports ``inf`` (CPU-only jax,
  or no jax) auto *is* the numpy path, so the ratio is pure dispatch
  overhead plus timing noise on an identical code path; the thresholds
  are documented noise floors rather than 1.0x for exactly that reason —
  at 1.0 the gate would coin-flip on same-path jitter, while a backend
  mispick shows up far below them.  The dispatch overhead is O(1)
  (one memoized resolution), so the large-arena row sits at ~1.0x and
  gates at 0.9x; the small-arena row divides the same microseconds by a
  ~80us baseline and gates at 0.85x;
* the ``stack_jax_vs_onehot`` row of the same bench — the fused jitted
  segment reduction must beat the retired one-hot matmul kernel it
  replaced (>= 1.0x; in practice it is orders of magnitude ahead).  The
  row only exists where jax is importable; a CSV without it is accepted
  when produced on a jax-less host;
* the ``llm_sweep_stacked`` row of :mod:`benchmarks.bench_llm_workloads`
  (the registry's ONE cross-machine ``best_strategy_many`` arena vs the
  per-pattern ``best_strategy`` loop on the same bound phases, verdicts
  asserted identical inside the bench) — the stacked all-scenario sweep
  must never lose to the per-scenario loop it replaced (>= 1.0x);
* the ``exec_agreement_crossover`` row of :mod:`benchmarks.bench_exec`
  (numpy-only) — the *calibrated* model (fitted from recorded sweeps,
  never shown ground truth) must call every direct-vs-aggregated
  crossover case on the Lassen-like sweep: the small end where
  ``device_direct`` wins, the large end where ``host_staged`` wins, and
  the flip itself (>= 1.0, i.e. exact);
* the ``exec_standard_vs_naive`` row of the same bench — the greedy
  edge-colored lowering of the ``standard`` schedule vs the naive
  one-``ppermute``-per-message lowering of the same exchange on the
  forced 8-device host mesh, delivered payloads asserted identical inside
  the bench — fusing messages into permutation rounds must never lose to
  the per-message loop (>= 1.0x).  Like ``stack_jax_vs_onehot`` the row
  only exists where jax is importable, so it is optional in a CSV from a
  jax-less host.

Usage::

    python -m benchmarks.perf_smoke [bench.csv]

With a CSV argument (the ``benchmarks.run`` output, as in CI) the gates are
applied to its rows without re-running the workloads; without one the
benchmarks are executed directly (local development).
"""
from __future__ import annotations

import sys

STACK_ROWS = ("stack_model_ladder", "stack_simulate", "stack_best_strategy")
DELTA_ROWS = ("delta_local_search_64", "delta_service_qps")
#: autotuned-default rows: same-code-path comparison -> noise-floor gate
AUTO_ROWS = ("stack_auto_small", "stack_auto_large")
#: fused-kernel-vs-retired-one-hot row: present only where jax imports
JAX_ROWS = ("stack_jax_vs_onehot",)
#: registry cross-machine arena vs per-scenario loop (numpy-only)
LLM_ROWS = ("llm_sweep_stacked",)
#: calibrated-model crossover agreement (numpy-only, always present)
EXEC_ROWS = ("exec_agreement_crossover",)
#: colored-vs-naive lowered schedule: present only where jax imports
EXEC_JAX_ROWS = ("exec_standard_vs_naive",)

GATED_ROWS = (STACK_ROWS + DELTA_ROWS + AUTO_ROWS + JAX_ROWS + LLM_ROWS
              + EXEC_ROWS + EXEC_JAX_ROWS)
OPTIONAL_ROWS = frozenset(JAX_ROWS + EXEC_JAX_ROWS)

#: per-row minimum ``derived`` speedup (see the module docstring)
THRESHOLD = {name: 1.0 for name in GATED_ROWS}
THRESHOLD["stack_auto_small"] = 0.85      # O(1) dispatch / tiny baseline
THRESHOLD["stack_auto_large"] = 0.9

#: reference path and unit per row family, for the report line
_REF = {**{n: ("loop", "us/sweep") for n in STACK_ROWS},
        **{n: ("rebuild", "us/search") for n in DELTA_ROWS},
        **{n: ("numpy", "us/eval") for n in AUTO_ROWS},
        **{n: ("one-hot", "us/reduce") for n in JAX_ROWS},
        **{n: ("loop", "us/sweep") for n in LLM_ROWS},
        **{n: ("simulator", "us/sweep") for n in EXEC_ROWS},
        **{n: ("naive", "us/run") for n in EXEC_JAX_ROWS}}
_REF["delta_service_qps"] = ("rebuild", "us/query")


def _rows_from_csv(path: str):
    rows = []
    with open(path) as f:
        for line in f:
            parts = line.strip().split(",")
            if parts and parts[0] in GATED_ROWS:
                rows.append((parts[0], float(parts[1]), float(parts[2])))
    missing = set(GATED_ROWS) - {name for name, _, _ in rows} - OPTIONAL_ROWS
    if missing:
        raise SystemExit(f"{path} is missing gated rows {sorted(missing)} — "
                         "did benchmarks.run fail before producing them?")
    return rows


def main() -> None:
    if len(sys.argv) > 1:
        rows = _rows_from_csv(sys.argv[1])
    else:
        from .bench_delta import bench_delta_local_search, bench_service_qps
        from .bench_exec import bench_exec_agreement, bench_exec_schedules
        from .bench_kernels import bench_phase_stack
        from .bench_llm_workloads import bench_llm_workloads
        from .bench_stack_backends import bench_stack_backends
        rows = (bench_phase_stack() + bench_delta_local_search()
                + bench_service_qps()
                + [r for r in bench_stack_backends() if r[0] in GATED_ROWS]
                + [r for r in bench_llm_workloads() if r[0] in GATED_ROWS]
                + [r for r in bench_exec_agreement() if r[0] in GATED_ROWS]
                + [r for r in bench_exec_schedules() if r[0] in GATED_ROWS])
    failed = False
    for name, us, speedup in rows:
        ref, unit = _REF[name]
        floor = THRESHOLD[name]
        ok = speedup >= floor
        status = "ok" if ok else f"SLOWER THAN {ref.upper()} (< {floor}x)"
        print(f"{name}: {us:.0f} {unit}, {speedup:.2f}x vs {ref}  "
              f"[{status}]")
        failed |= not ok
    if failed:
        sys.exit(1)


if __name__ == "__main__":
    main()
