"""LLM workload benchmarks: derivation throughput + the stacked sweep gate.

Two rows:

* ``llm_derive_patterns`` — wall time to derive every registry scenario's
  patterns from scratch (seeded routing histograms, ring lowering, pipeline
  schedule); ``derived`` is the total message count, a quick sanity
  fingerprint of the derivation.
* ``llm_sweep_stacked`` — the registry's cross-machine pricing call (ONE
  ``best_strategy_many`` over every scenario x machine candidate, stacked
  per machine group inside) vs the per-pattern ``best_strategy`` loop over
  the same bound phases.  Verdicts are asserted identical before timing;
  ``derived`` is the speedup, gated >= 1.0x by ``perf_smoke`` — the single
  arena must never lose to the loop it replaced.
"""
from __future__ import annotations

import time


def _best_of(fn, reps: int = 3, trials: int = 4):
    """Best-of-N mean wall time (us) — robust against CI-runner throttling."""
    out = fn()
    best = float("inf")
    for _ in range(trials):
        t0 = time.perf_counter()
        for _ in range(reps):
            out = fn()
        best = min(best, (time.perf_counter() - t0) / reps)
    return best * 1e6, out


def bench_llm_workloads():
    from repro.comm.strategies import best_strategy, best_strategy_many
    from repro.workloads import (DEFAULT_SCENARIOS, default_machines,
                                 scenario_patterns)

    rows = []

    def derive():
        return [(sc, scenario_patterns(sc)) for sc in DEFAULT_SCENARIOS]

    us_derive, derived = _best_of(derive, reps=2)
    n_msgs = sum(pat.n_msgs for _, phases in derived for _, pat in phases)
    rows.append(("llm_derive_patterns", us_derive, float(n_msgs)))

    machines = default_machines()
    bound = [pat.bind(m) for m in machines.values()
             for _, phases in derived for _, pat in phases]

    us_loop, ref = _best_of(lambda: [best_strategy(ph) for ph in bound],
                            reps=2)
    us_stack, got = _best_of(lambda: best_strategy_many(bound), reps=2)
    assert [(v.model_winner, v.sim_winner, v.model, v.sim) for v in got] == \
           [(v.model_winner, v.sim_winner, v.model, v.sim) for v in ref], \
        "stacked cross-machine sweep drifted from the per-pattern loop"
    rows.append(("llm_sweep_stacked", us_stack, us_loop / us_stack))
    return rows


ALL_BENCHES = [bench_llm_workloads]
