"""Kernel benchmarks: jitted wall time per call (CPU; interpret-mode
correctness is asserted, timing uses the pure-jnp reference path which is
what actually executes on CPU) + allclose error vs oracle as ``derived``."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.topology import TorusTopology
from repro.kernels.flash_attention import flash_attention
from repro.kernels.ssd import ssd_intra_chunk
from repro.kernels.spmv_ell import spmv_block_ell, csr_to_block_ell
from repro.kernels import ref
from repro.sparse import elasticity_like_3d


def _time_call(fn, *args, reps=3):
    fn(*args)  # compile/warm
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6


def bench_flash_attention():
    rng = np.random.default_rng(0)
    B, S, H, KH, D = 1, 512, 8, 2, 64
    q = jnp.asarray(rng.standard_normal((B, S, H, D)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, S, KH, D)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, S, KH, D)), jnp.float32)
    out_k = flash_attention(q, k, v, causal=True, interpret=True)
    out_r = ref.flash_attention_ref(q, k, v, causal=True)
    err = float(jnp.max(jnp.abs(out_k - out_r)))
    us = _time_call(jax.jit(lambda *a: ref.flash_attention_ref(*a)), q, k, v)
    return [("kernel_flash_attention_512", us, err)]


def bench_ssd():
    rng = np.random.default_rng(1)
    G, q, n, p = 16, 128, 128, 64
    dtx = jnp.asarray(rng.standard_normal((G, q, p)), jnp.float32)
    Bm = jnp.asarray(rng.standard_normal((G, q, n)), jnp.float32)
    Cm = jnp.asarray(rng.standard_normal((G, q, n)), jnp.float32)
    cumA = jnp.cumsum(-jnp.asarray(rng.uniform(0.001, 0.1, (G, q, 1)),
                                   jnp.float32), axis=1)
    y_k, s_k = ssd_intra_chunk(dtx, Bm, Cm, cumA, interpret=True)
    y_r, s_r = ref.ssd_intra_chunk_ref(dtx, Bm, Cm, cumA)
    err = float(max(jnp.max(jnp.abs(y_k - y_r)), jnp.max(jnp.abs(s_k - s_r))))
    us = _time_call(jax.jit(lambda *a: ref.ssd_intra_chunk_ref(*a)),
                    dtx, Bm, Cm, cumA)
    return [("kernel_ssd_intra_chunk_128", us, err)]


def bench_spmv():
    rng = np.random.default_rng(2)
    A = elasticity_like_3d(8)     # 1536 rows, 3-dof blocks
    blocks, cols, max_bpr = csr_to_block_ell(A, bs=8)
    x = jnp.asarray(rng.standard_normal(blocks.shape[0] * 8), jnp.float32)
    y_k = spmv_block_ell(blocks, cols, x, interpret=True)
    y_r = ref.spmv_block_ell_ref(blocks, cols, x)
    err = float(jnp.max(jnp.abs(y_k - y_r)))
    us = _time_call(jax.jit(lambda *a: ref.spmv_block_ell_ref(*a)),
                    blocks, cols, x)
    # density of the block-ELL padding (fraction of stored entries that are
    # structural nonzeros) — the bs trade-off the DESIGN discusses
    density = A.nnz / blocks.size
    return [("kernel_spmv_block_ell_1536", us, err),
            ("kernel_spmv_block_ell_density", us, float(density))]


def bench_torus_routing():
    """Vectorized dimension-ordered routing (the CommPhase contention path).

    Times the per-dimension segment expansion + per-link byte accumulation on
    a big message batch; ``derived`` is the max relative per-link error vs the
    scalar ``route_links`` reference on a subsample (expected 0).
    """
    t = TorusTopology((8, 8, 8), wrap=False)
    rng = np.random.default_rng(0)
    n = 20000
    src = rng.integers(0, t.size, n)
    dst = rng.integers(0, t.size, n)
    size = rng.integers(64, 1 << 20, n).astype(float)
    t.link_bytes(src, dst, size)  # warm
    t0 = time.perf_counter()
    reps = 3
    for _ in range(reps):
        dense = t.link_bytes(src, dst, size)
    us = (time.perf_counter() - t0) / reps * 1e6
    # correctness vs scalar reference on a subsample
    k = 300
    ref_acc: dict = {}
    for s, d, z in zip(src[:k], dst[:k], size[:k]):
        for link in t.route_links(int(s), int(d)):
            ref_acc[link] = ref_acc.get(link, 0.0) + float(z)
    sub = t.link_bytes(src[:k], dst[:k], size[:k])
    err = 0.0
    for (node, dim, _), v in ref_acc.items():
        err = max(err, abs(sub[node * t.ndim + dim] - v) / v)
    hops = int(t.hops(src, dst).sum())
    return [("kernel_torus_route_20k_msgs", us, err),
            ("kernel_torus_route_links_per_sec", us, hops / (us * 1e-6))]


def _best_of(fn, reps: int = 3, trials: int = 4):
    """Best-of-N mean wall time (us) — robust against CI-runner throttling."""
    out = fn()                                  # warm caches / first-call work
    best = float("inf")
    for _ in range(trials):
        t0 = time.perf_counter()
        for _ in range(reps):
            out = fn()
        best = min(best, (time.perf_counter() - t0) / reps)
    return best * 1e6, out


def bench_phase_stack():
    """PhaseStack sweep throughput vs the per-phase loop (DESIGN.md §8).

    Workload: the AMG hierarchy x partition scan (SpMV halo exchanges of a
    Poisson hierarchy partitioned at 13 process counts — the paper's sweep
    axis), all phases prebound to one machine.  Each row times the *sweep
    evaluation*: the loop path prices/simulates phase by phase
    (``phase_cost_phase`` / ``simulate``, the pre-stack code path, still the
    mixed-machine fallback), the stacked path goes through the PhaseStack
    fast path of the same batched entry points.  Construction (pattern
    extraction, binding, strategy rewrites, arrival draws) is shared
    preprocessing, excluded from both sides.  ``derived`` is the speedup;
    results are asserted bit-identical before timing.
    """
    import numpy as onp
    from repro.comm import PhaseStack, STRATEGIES, rewrite
    from repro.core import (MODEL_LEVELS, model_ladder_many, phase_cost_many,
                            phase_cost_phase)
    from repro.net import blue_waters_machine, simulate, simulate_many
    from repro.sparse import RowPartition, build_hierarchy, poisson_3d, \
        spmv_comm_pattern

    machine = blue_waters_machine((4, 4, 2))
    levels = build_hierarchy(poisson_3d(12), theta=0.25)

    def scan_phases(procs):
        out = []
        for nproc in procs:
            for lvl in levels:
                part = RowPartition.balanced(
                    lvl.A.n_rows, min(nproc, max(lvl.A.n_rows // 2, 2)))
                cp = spmv_comm_pattern(lvl.A, part)
                if cp.n_msgs:
                    out.append(cp.bind(machine))
        return out

    rows = []
    phases = scan_phases((8, 12, 16, 24, 32, 48, 64, 96, 128, 192, 256,
                          384, 512))
    stack = PhaseStack.build(phases)

    # -- model ladder x hierarchy x partitions -------------------------------
    us_loop, ref = _best_of(
        lambda: [{lvl: phase_cost_phase(ph, level=lvl)
                  for lvl in MODEL_LEVELS} for ph in phases], reps=2)
    us_stack, got = _best_of(lambda: model_ladder_many(stack), reps=5)
    assert got == ref, "stacked ladder drifted from the per-phase loop"
    rows.append(("stack_model_ladder", us_stack, us_loop / us_stack))

    # -- simulator sweep, random envelope arrival ----------------------------
    arrivals = [ph.random_arrival_flat(onp.random.default_rng(0))
                for ph in phases]
    us_loop, ref = _best_of(
        lambda: [simulate(ph, arrival_order=ao)
                 for ph, ao in zip(phases, arrivals)], reps=2)
    us_stack, got = _best_of(
        lambda: simulate_many(stack, arrival_orders=arrivals), reps=2)
    assert all(g.time == r.time and g.queue == r.queue
               and g.contention == r.contention
               for g, r in zip(got, ref)), "stacked simulate drifted"
    rows.append(("stack_simulate", us_stack, us_loop / us_stack))

    # -- strategy candidate set: every (pattern, strategy) phase sequence ----
    cand_phases, cand_arrivals = [], []
    for ph in scan_phases((8, 12, 16, 24, 32, 48, 64, 96, 128)):
        for name in STRATEGIES:
            plan = rewrite(ph, name)
            rng = onp.random.default_rng(0)
            cand_phases.extend(plan.phases)
            cand_arrivals.extend(p.random_arrival_flat(rng)
                                 for p in plan.phases)
    cstack = PhaseStack.build(cand_phases)
    us_loop, ref = _best_of(
        lambda: ([phase_cost_phase(p).total for p in cand_phases],
                 [simulate(p, arrival_order=a).time
                  for p, a in zip(cand_phases, cand_arrivals)]), reps=2)
    us_stack, got = _best_of(
        lambda: ([c.total for c in phase_cost_many(cstack)],
                 [r.time for r in simulate_many(
                     cstack, arrival_orders=cand_arrivals)]), reps=2)
    assert got == ref, "stacked strategy sweep drifted"
    rows.append(("stack_best_strategy", us_stack, us_loop / us_stack))
    return rows


ALL_BENCHES = [bench_flash_attention, bench_ssd, bench_spmv,
               bench_torus_routing, bench_phase_stack]
