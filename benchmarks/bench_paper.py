"""Paper-reproduction benchmarks — one per figure/table of Bienz et al. 2018.

Each function returns rows of (name, us_per_call, derived):
  * us_per_call — wall time of the benchmark body per evaluation;
  * derived     — the figure's headline quantity (fit ratios, model accuracy).

"Measured" data comes from the mechanistic simulator (the CommPhase engine's
event-level side, DESIGN.md §4) instantiated with the paper's Table-1 ground
truth; both model and simulator sweep the AMG hierarchy through the batched
``CommPhase`` entry points (DESIGN.md §1).
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import (blue_waters, model_ladder_many, MODEL_LEVELS)
from repro.core.fitting import (fit_alpha_beta, fit_RN, fit_gamma, fit_delta)
from repro.core.params import PROTOCOL_NAMES
from repro.core.topology import contention_ell, average_hops
from repro.net import (blue_waters_machine, simulate, simulate_phase,
                       simulate_many, pingpong_sweep, ppn_sweep,
                       high_volume_pingpong, contention_line_test)
from repro.sparse import (elasticity_like_3d, build_hierarchy, RowPartition,
                          spmv_comm_pattern, spgemm_comm_pattern)


def _timed(fn):
    t0 = time.perf_counter()
    out = fn()
    return out, (time.perf_counter() - t0) * 1e6


# ---------------------------------------------------------- Fig 2/3 ---------
def bench_fig2_fig3_node_aware():
    """Ping-pong sweeps: node-aware split vs single-class max-rate."""
    m = blue_waters_machine((2, 1, 1))
    gt = m.params
    sizes = np.unique(np.round(np.logspace(0, 6, 40)).astype(int))
    rows = []

    def run():
        errs_na, errs_flat = [], []
        for li, kind in enumerate(gt.locality_names):
            meas = pingpong_sweep(m, kind, sizes, reps=2, noise=0.0)
            from repro.core.models import message_time
            pred_na = message_time(gt, sizes, np.full(sizes.shape, li))
            pred_flat = message_time(gt, sizes,
                                     np.full(sizes.shape, li),
                                     node_aware=False)
            errs_na.append(np.abs(pred_na - meas) / meas)
            errs_flat.append(np.abs(pred_flat - meas) / meas)
        return float(np.mean(np.concatenate(errs_na))), \
            float(np.mean(np.concatenate(errs_flat)))

    (err_na, err_flat), us = _timed(run)
    rows.append(("fig2_flat_model_relerr", us, err_flat))
    rows.append(("fig3_node_aware_relerr", us, err_na))
    return rows


# ---------------------------------------------------------- Table 1 ---------
def bench_table1_parameter_fit():
    """Recover the Table-1 (alpha, R_b, R_N) from simulated ping-pongs."""
    m = blue_waters_machine((2, 1, 1))
    gt = m.params
    sizes = np.unique(np.round(np.logspace(0, 6, 48)).astype(int))

    def run():
        worst = 0.0
        for li, kind in enumerate(gt.locality_names):
            meas = pingpong_sweep(m, kind, sizes, reps=2, noise=0.0)
            fit = fit_alpha_beta(sizes, meas, gt)
            for pi, proto in enumerate(PROTOCOL_NAMES):
                a, rb = fit[proto]
                worst = max(worst, abs(a - gt.alpha[li, pi]) / gt.alpha[li, pi],
                            abs(rb - gt.Rb[li, pi]) / gt.Rb[li, pi])
        ks, ts = ppn_sweep(m, 1e6)
        rn = fit_RN(ks, ts, 1e6, gt.alpha[2, 2], gt.Rb[2, 2])
        worst = max(worst, abs(rn - 6.6e9) / 6.6e9)
        return worst

    worst, us = _timed(run)
    return [("table1_fit_worst_param_relerr", us, worst)]


# ---------------------------------------------------------- Fig 4/5 ---------
def bench_fig4_fig5_queue_search():
    """HighVolumePingPong: reversed-order quadratic queue cost; gamma fit."""
    m = blue_waters_machine((2, 1, 1))
    gt = m.params
    ns = np.array([100, 300, 1000, 3000])
    total_bytes = 1 << 22

    def run():
        meas, base = [], []
        for n in ns:
            s = total_bytes // n
            t_rev, *_ = high_volume_pingpong(m, [(0, 32)], int(n), s,
                                             order="reversed")
            t_same, *_ = high_volume_pingpong(m, [(0, 32)], int(n), s,
                                              order="same")
            meas.append(t_rev)
            base.append(t_same)
        return fit_gamma(ns, np.array(meas), np.array(base))

    g, us = _timed(run)
    return [("fig5_gamma_fit_ratio", us, g / gt.gamma)]


# ---------------------------------------------------------- Fig 7/9 ---------
def bench_fig7_fig9_contention():
    """Gemini-line contention: model misses it w/o delta, captures it with."""
    m = blue_waters_machine((4, 1, 1))
    gt = m.params

    def run():
        ells, meas, base = [], [], []
        for n, s in [(1, 1e6), (4, 2.5e5), (16, 62500), (4, 1e6)]:
            tot, r1, r2 = contention_line_test(m, n, s)
            # model without contention = transport + queue terms of the sim
            base.append((r1.transport + r1.queue)
                        + (r2.transport + r2.queue))
            meas.append(tot)
            b = 2 * n * s * 32 / (32 * 4)    # avg bytes/proc over the phase
            ells.append(2 * contention_ell(4, 1, b, 32) / 2)
        d = fit_delta(np.array(ells), np.array(meas), np.array(base))
        return d

    d, us = _timed(run)
    return [("fig9_delta_fit_ratio", us, d / gt.delta)]


# --------------------------------------------------------- Fig 1/10/11 ------
def _amg_phases(machine, levels, opname, max_procs=1024):
    """One machine-bound CommPhase per AMG level (empty patterns skipped).

    Returns (level index, CommPhase) pairs; locality, protocol, routing
    endpoints and active-sender counts are cached once per phase and shared
    by the model ladder and the simulator below.
    """
    out = []
    for li, lvl in enumerate(levels):
        Al = lvl.A
        n_procs = min(max_procs, max(Al.n_rows // 2, 2))
        part = RowPartition.balanced(Al.n_rows, n_procs)
        if opname == "spmv":
            cp = spmv_comm_pattern(Al, part)
        else:
            P = levels[li + 1].P if li + 1 < len(levels) else None
            if P is None:
                break
            cp = spgemm_comm_pattern(Al, P, part)
        if cp.n_msgs == 0:
            continue
        out.append((li, cp.bind(machine)))
    return out


def bench_amg_spmv_spgemm(save_json: str | None = None):
    """SpMV (Fig 10) and SpGEMM (Fig 11) across the AMG hierarchy.

    Reproduced claims (the paper's Sec. 5 reading):
      * transport-only models (node-aware max-rate) UNDER-predict the
        message-heavy levels by exactly the queue+contention share;
      * adding the gamma*n^2 queue term closes most of that gap;
      * the contention term is an upper-bound style estimate that brackets
        from above (the paper itself reports over-prediction).

    "Measured" uses the paper's Sec-5 irregular regime: random envelope
    arrival, so receives match at ~n^2/3 queue positions.
    """
    A = elasticity_like_3d(14)       # 8232-dof elasticity-like operator
    levels = build_hierarchy(A, theta=0.25)
    machine = blue_waters_machine((4, 4, 2))  # 32 Geminis = 1024 ppn total

    rows = []
    detail = []
    for opname in ("spmv", "spgemm_AP"):
        t0 = time.perf_counter()
        tagged = _amg_phases(machine, levels,
                             "spmv" if opname == "spmv" else "spgemm")
        phases = [ph for _, ph in tagged]
        arrivals = [ph.random_arrival_order(np.random.default_rng(0))
                    for ph in phases]
        measured = [r.time for r in
                    simulate_many(phases, arrival_orders=arrivals)]
        ladders = model_ladder_many(phases)
        under_na, err_q, share = [], [], []
        for (li, ph), meas, lad in zip(tagged, measured, ladders):
            mod = {lvl: b.total for lvl, b in lad.items()}
            under_na.append((meas - mod["node_aware"]) / meas)
            err_q.append(abs(mod["queue"] - meas) / meas)
            share.append(1.0 - mod["node_aware"] / meas)
            Al = levels[li].A
            detail.append({
                "op": opname, "level": li, "rows": int(Al.n_rows),
                "nnz_per_row": float(Al.nnz / Al.n_rows),
                "procs": ph.n_procs,
                "max_msgs_per_proc": int(ph.max_msgs_per_proc()),
                "measured": meas,
                **{k: v for k, v in mod.items()},
            })
        us = (time.perf_counter() - t0) * 1e6
        rows.append((f"fig10_11_{opname}_node_aware_underprediction", us,
                     float(np.max(under_na))))
        rows.append((f"fig10_11_{opname}_plus_queue_relerr", us,
                     float(np.mean(err_q))))
        rows.append((f"fig10_11_{opname}_queue_contention_share", us,
                     float(np.max(share))))
    if save_json:
        import json
        with open(save_json, "w") as f:
            json.dump(detail, f, indent=1)
    return rows


# ------------------------------------------------- simulator throughput -----
def bench_simulator_throughput():
    """Simulator throughput (messages/sec) on the message-heaviest AMG level.

    Tracks the CommPhase engine's headline speedup: vectorized max-rate
    transport, one-shot dimension-ordered link routing, and the batched
    receive-queue Fenwick walk.  ``cold`` rebuilds the CommPhase every call
    (the full ``simulate_phase`` path); ``prebuilt`` reuses the cached phase
    as a hierarchy sweep via ``simulate_many`` would.
    """
    A = elasticity_like_3d(14)
    levels = build_hierarchy(A, theta=0.25)
    machine = blue_waters_machine((4, 4, 2))
    _, phase = max(_amg_phases(machine, levels, "spmv"),
                   key=lambda t: t[1].n_msgs)
    arrival = phase.random_arrival_order(np.random.default_rng(0))
    reps = 5
    simulate(phase, arrival_order=arrival)            # warm numpy caches
    t0 = time.perf_counter()
    for _ in range(reps):
        simulate_phase(machine, phase.src, phase.dst, phase.size,
                       arrival_order=arrival)
    us_cold = (time.perf_counter() - t0) / reps * 1e6
    t0 = time.perf_counter()
    for _ in range(reps):
        simulate(phase, arrival_order=arrival)
    us_warm = (time.perf_counter() - t0) / reps * 1e6
    n = phase.n_msgs
    return [("sim_throughput_msgs_per_sec", us_cold, n / (us_cold * 1e-6)),
            ("sim_throughput_prebuilt_msgs_per_sec", us_warm,
             n / (us_warm * 1e-6))]


def bench_strategy_crossover():
    """Node-aware strategy sweep over the AMG hierarchy (NAPSpMV question).

    Rows:
      * how many levels the simulator flips to an aggregated strategy;
      * how often the model ladder's predicted winner matches the simulator's
        verdict (the strategy-selection analogue of the accuracy figures);
      * the best simulated speedup an aggregated strategy delivers over
        standard on any level.
    """
    from repro.comm import best_strategy

    A = elasticity_like_3d(14)
    levels = build_hierarchy(A, theta=0.25)
    machine = blue_waters_machine((4, 4, 2))

    def run():
        verdicts = [best_strategy(ph, seed=0)
                    for _, ph in _amg_phases(machine, levels, "spmv")]
        flipped = sum(v.sim_winner != "standard" for v in verdicts)
        agree = np.mean([v.agree for v in verdicts])
        speedup = max(v.sim["standard"] / v.sim[v.sim_winner]
                      for v in verdicts)
        return flipped, float(agree), float(speedup)

    (flipped, agree, speedup), us = _timed(run)
    return [("strategy_levels_flipped_to_aggregated", us, flipped),
            ("strategy_model_sim_winner_agreement", us, agree),
            ("strategy_best_sim_speedup_vs_standard", us, speedup)]


def bench_strategy_rewrite_throughput():
    """Rewrite + simulate throughput for the aggregated strategies.

    The rewrites must stay array-rate (np.unique/bincount, no per-message
    Python loops); these rows make a regression visible just like the
    ``sim_throughput_*`` rows do for the engine.  Throughput counts original
    messages per second through the full rewrite + sequence simulation.
    """
    from repro.comm import rewrite
    from repro.net import simulate_sequence

    A = elasticity_like_3d(14)
    levels = build_hierarchy(A, theta=0.25)
    machine = blue_waters_machine((4, 4, 2))
    _, phase = max(_amg_phases(machine, levels, "spmv"),
                   key=lambda t: t[1].n_msgs)
    reps, rows = 3, []
    for name in ("two_step", "three_step"):
        simulate_sequence(rewrite(phase, name).phases)    # warm caches
        t0 = time.perf_counter()
        for _ in range(reps):
            simulate_sequence(rewrite(phase, name).phases)
        us = (time.perf_counter() - t0) / reps * 1e6
        rows.append((f"sim_throughput_{name}_msgs_per_sec", us,
                     phase.n_msgs / (us * 1e-6)))
    return rows


def bench_hetero_gpu_strategies():
    """Heterogeneous nodes (Lockhart et al. 2022): host-staged vs GPU-direct.

    Rows:
      * the message count at which the Lassen-like preset's simulator verdict
        flips from ``device_direct`` to ``host_staged`` (the crossover);
      * how often the model ladder predicts the simulator's winner across
        the whole sweep on both hetero presets;
      * the simulated speedups at the sweep's endpoints (direct over staged
        at the small end, staged over direct at the large end);
      * whether the Frontier-like preset (NICs on the GPUs) ever leaves the
        direct path (it should not: derived value 1.0 = always direct).
    """
    from repro.comm import CommPhase, GPU_STRATEGIES, best_strategy_many
    from repro.net import frontier_machine, lassen_machine

    counts = (8, 32, 128, 512, 2048)

    def phases_for(machine):
        out = []
        for n in counts:
            rng = np.random.default_rng(42)
            P = machine.n_procs
            src = rng.integers(0, P, n)
            dst = (src + rng.integers(1, P, n)) % P
            size = rng.integers(256, 8192, n).astype(float)
            out.append(CommPhase.build(machine, src, dst, size, n_procs=P))
        return out

    def run():
        lm, fm = lassen_machine((2, 2, 2)), frontier_machine((2, 2, 1))
        lv = best_strategy_many(phases_for(lm), strategies=GPU_STRATEGIES,
                                seed=0)
        fv = best_strategy_many(phases_for(fm), strategies=GPU_STRATEGIES,
                                seed=0)
        staged = [n for n, v in zip(counts, lv)
                  if v.sim_winner == "host_staged"]
        crossover = staged[0] if staged else 0
        agree = float(np.mean([v.agree for v in lv + fv]))
        small, large = lv[0].sim, lv[-1].sim
        direct_small = small["host_staged"] / small["device_direct"]
        staged_large = large["device_direct"] / large["host_staged"]
        frontier_direct = float(np.mean([v.sim_winner == "device_direct"
                                         for v in fv]))
        return crossover, agree, direct_small, staged_large, frontier_direct

    (crossover, agree, d_small, s_large, f_direct), us = _timed(run)
    return [("hetero_lassen_crossover_msgs", us, crossover),
            ("hetero_model_sim_winner_agreement", us, agree),
            ("hetero_lassen_direct_small_speedup", us, d_small),
            ("hetero_lassen_staged_large_speedup", us, s_large),
            ("hetero_frontier_direct_wins", us, f_direct)]


def bench_queue_position_n2_over_3():
    """Paper Sec. 5: random receive order costs ~n^2/3 (between n and n^2/2)."""
    from repro.net.simulator import queue_traversal_steps

    def run():
        n = 3000
        rng = np.random.default_rng(0)
        total = queue_traversal_steps(np.arange(n), rng.permutation(n)).sum()
        return float(total / (n * n))

    frac, us = _timed(run)
    return [("sec5_random_order_queue_n2_coeff", us, frac)]


ALL_BENCHES = [
    bench_fig2_fig3_node_aware,
    bench_table1_parameter_fit,
    bench_fig4_fig5_queue_search,
    bench_fig7_fig9_contention,
    bench_amg_spmv_spgemm,
    bench_strategy_crossover,
    bench_hetero_gpu_strategies,
    bench_queue_position_n2_over_3,
    bench_simulator_throughput,
    bench_strategy_rewrite_throughput,
]
