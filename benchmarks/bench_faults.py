"""Overhead of the robustness layer (fault sites, validation, degradation).

The hardened stack threads every device call through a named injection
site and (optionally) the typed validation layer; these rows pin what
that safety costs when nothing is wrong — and what a fully degraded sweep
costs relative to a clean one.

Rows (``name,us_per_call,derived``):

``faults_site_disarmed``
    One ``fail_point`` + ``poison`` probe with no specs armed — the cost
    every guarded device call pays always.  ``derived`` is 1.0.

``faults_site_armed_miss``
    The same probe with a non-matching spec armed (the worst common case:
    a chaos plan targeting *other* sites).  ``derived`` is the
    disarmed/armed time ratio.

``guard_validate_100k``
    :func:`repro.comm.guard.validate_messages` over a 100k-message
    pattern.  ``derived`` is validated messages per microsecond — the
    layer is a handful of vectorized reductions, so this should stay in
    the tens of messages/us.

``sweep_clean_numpy`` / ``sweep_degraded``
    One :func:`repro.comm.best_strategy` sweep of a 4k-message pattern on
    the numpy reference, then the same sweep on the jax backend with every
    fault site raising — the full degradation path (fault -> health event
    -> numpy fallback, quarantine warm after the first phases).
    ``derived`` for the degraded row is clean/degraded (how much a fully
    degraded sweep costs relative to the reference); skipped without jax.

Run directly for the CSV::

    PYTHONPATH=src python -m benchmarks.bench_faults
"""
from __future__ import annotations

import time

import numpy as np

VALIDATE_MSGS = 100_000
SWEEP_MSGS = 4_000
SITE_PROBES = 20_000


def _best_of(fn, reps: int = 3, trials: int = 4):
    out = fn()
    best = float("inf")
    for _ in range(trials):
        t0 = time.perf_counter()
        for _ in range(reps):
            out = fn()
        best = min(best, (time.perf_counter() - t0) / reps)
    return best * 1e6, out


def _probe_once():
    from repro.comm import faults
    for _ in range(SITE_PROBES):
        faults.fail_point("kernel.segment_reduce")
    return SITE_PROBES


def bench_fault_sites():
    from repro.comm import faults

    us_off, n = _best_of(_probe_once, reps=2)
    rows = [("faults_site_disarmed", us_off / n, 1.0)]
    with faults.inject("autotune.cache_write", "raise"):   # never matches
        us_miss, n = _best_of(_probe_once, reps=2)
    rows.append(("faults_site_armed_miss", us_miss / n, us_off / us_miss))
    return rows


def bench_validation():
    from repro.comm.guard import validate_messages

    rng = np.random.default_rng(0)
    P = 4096
    src = rng.integers(0, P, VALIDATE_MSGS)
    dst = rng.integers(0, P, VALIDATE_MSGS)
    size = rng.integers(1, 1 << 16, VALIDATE_MSGS).astype(np.float64)
    us, _ = _best_of(
        lambda: validate_messages(src, dst, size, n_procs=P) or 1, reps=3)
    return [("guard_validate_100k", us, VALIDATE_MSGS / us)]


def _sweep_pattern():
    from repro.net import blue_waters_machine
    from repro.sparse.partition import CommPattern

    machine = blue_waters_machine((2, 2, 2))
    rng = np.random.default_rng(1)
    P = machine.n_procs
    src = rng.integers(0, P, SWEEP_MSGS)
    dst = (src + rng.integers(1, P, SWEEP_MSGS)) % P
    size = rng.integers(1, 1 << 16, SWEEP_MSGS).astype(np.float64)
    return machine, CommPattern(src=src, dst=dst, size=size, n_procs=P)


def bench_degraded_sweep():
    import warnings

    from repro.comm import faults
    from repro.comm.health import reset_health
    from repro.comm.strategies import best_strategy
    from repro.kernels.comm_stack import have_jax

    machine, pat = _sweep_pattern()
    us_clean, clean = _best_of(
        lambda: best_strategy(pat, machine, backend="numpy"), reps=2)
    rows = [("sweep_clean_numpy", us_clean, 1.0)]
    if have_jax():
        def degraded():
            reset_health()              # re-arm quarantine per timed pass
            with warnings.catch_warnings():
                warnings.simplefilter("ignore")
                with faults.inject("*", "raise"):
                    return best_strategy(pat, machine, backend="jax")
        us_deg, verdict = _best_of(degraded, reps=2)
        assert verdict.degraded and verdict.model == clean.model, \
            "degraded sweep drifted from the numpy reference"
        rows.append(("sweep_degraded", us_deg, us_clean / us_deg))
        reset_health()
    return rows


ALL_BENCHES = [bench_fault_sites, bench_validation, bench_degraded_sweep]


def main() -> None:
    print("name,us_per_call,derived")
    for bench in ALL_BENCHES:
        for name, us, derived in bench():
            print(f"{name},{us:.1f},{derived:.6g}", flush=True)


if __name__ == "__main__":
    main()
