"""Roofline benchmark: summarize the dry-run artifacts into the three-term
table (compute / memory / collective) per (arch x shape x mesh) cell."""
from __future__ import annotations

import glob
import json
import os

from repro.core.params import (V5E_PEAK_FLOPS_BF16, V5E_HBM_BW)

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts", "dryrun")


def load_cells(pattern: str = "*.json"):
    cells = []
    for f in sorted(glob.glob(os.path.join(ART, pattern))):
        a = json.load(open(f))
        if a.get("status") == "ok":
            cells.append(a)
    return cells


def roofline_terms(a: dict) -> dict:
    """Three terms in seconds (per-device cost_analysis -> per-chip times)."""
    flops = a["cost"]["flops_per_device"]
    byts = a["cost"]["bytes_per_device"]
    cm = a["comm_model"]
    compute = flops / V5E_PEAK_FLOPS_BF16
    memory = byts / V5E_HBM_BW
    coll_naive = cm["naive_time"]
    coll_model = cm["model_time"]
    dominant = max((compute, "compute"), (memory, "memory"),
                   (coll_model, "collective"))[1]
    # MODEL_FLOPS: 6*N_active*D for train (fwd+bwd), 2*N_active*D for inference
    tokens = (a["global_batch"] * a["seq_len"] if a["kind"] != "decode"
              else a["global_batch"])
    mult = 6 if a["kind"] == "train" else 2
    chips = 512 if "2x16x16" in a["mesh"] else 256
    model_flops = mult * a["n_active_params"] * tokens / chips
    return {
        "compute_s": compute, "memory_s": memory,
        "coll_naive_s": coll_naive, "coll_model_s": coll_model,
        "dominant": dominant,
        "model_hlo_ratio": model_flops / flops if flops else 0.0,
        "roofline_frac": max(compute, memory) / (compute + memory + coll_model)
        if (compute + memory + coll_model) > 0 else 0.0,
    }


def bench_roofline_table():
    cells = load_cells()
    rows = []
    worst = (1.0, None)
    n_fit = 0
    for a in cells:
        t = roofline_terms(a)
        frac = t["roofline_frac"]
        if frac < worst[0]:
            worst = (frac, f"{a['arch']}x{a['shape']}x{a['mesh']}")
        n_fit += a["memory"]["peak_bytes"] < 15.5 * 2**30
    if cells:
        rows.append(("roofline_cells_ok", 0.0, float(len(cells))))
        rows.append(("roofline_cells_fit_hbm", 0.0, float(n_fit)))
        rows.append(("roofline_worst_fraction", 0.0, worst[0]))
    else:
        rows.append(("roofline_cells_ok", 0.0, 0.0))
    return rows


ALL_BENCHES = [bench_roofline_table]
