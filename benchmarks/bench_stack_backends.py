"""Arena-size x backend sweep for the PhaseStack reduction backends (PR 6).

Rows (``name,us_per_call,derived``):

``stack_backend_numpy_{small,large}``
    Baseline: one uncached ``cost_arrays`` evaluation on the numpy backend
    (a fresh ``dataclasses.replace`` clone of the params is passed per call
    so the pricing cache can never hide the work).  ``derived`` is 1.0.

``stack_auto_{small,large}``
    The same evaluation under ``backend='auto'``.  ``derived`` is the
    numpy/auto time ratio — the :mod:`benchmarks.perf_smoke` gate requires
    it never drops below its noise floor (0.9x): the autotuned default must
    never pick a backend slower than numpy.  On hosts without an
    accelerator the probe reports an infinite crossover and auto *is* the
    numpy path, so the ratio measures pure dispatch overhead.

``stack_jax_large``
    Device (jitted jax) backend on the large arena; ``derived`` is the
    numpy/jax ratio.  Informational: on CPU-only hosts jax loses to
    numpy — exactly why the autotuner exists.  Skipped without jax.

``stack_jax_vs_onehot``
    The acceptance row: the fused jitted segment-sum against the retired
    one-hot matmul reduction it replaced (reimplemented locally below as
    the reference), same data, both device-resident and jitted.
    ``derived`` is onehot/fused — gated >= 1.0 in perf_smoke.  Skipped
    without jax.

Run directly for a CSV (and a ``BENCH_stack.json`` artifact)::

    PYTHONPATH=src python -m benchmarks.bench_stack_backends [out.json]
"""
from __future__ import annotations

import dataclasses
import time

import numpy as np

SMALL_MSGS = 2_000
LARGE_MSGS = 260_000
ONEHOT_MSGS = 8_192
ONEHOT_SEGS = 2_048


def _best_of(fn, reps: int = 3, trials: int = 4):
    out = fn()                                  # warm caches / first-call work
    best = float("inf")
    for _ in range(trials):
        t0 = time.perf_counter()
        for _ in range(reps):
            out = fn()
        best = min(best, (time.perf_counter() - t0) / reps)
    return best * 1e6, out


def _arena(total_msgs: int, n_phases: int = 8, seed: int = 0):
    """A ragged BW stack with ~total_msgs messages across n_phases phases."""
    from repro.comm import CommPhase, PhaseStack
    from repro.net import blue_waters_machine

    machine = blue_waters_machine((4, 4, 2))
    rng = np.random.default_rng(seed)
    P = machine.n_procs
    per = np.maximum(1, rng.multinomial(total_msgs, np.full(n_phases,
                                                            1 / n_phases)))
    phases = []
    for n in per:
        src = rng.integers(0, P, n)
        dst = (src + rng.integers(1, P, n)) % P
        size = rng.integers(1, 1 << 16, n).astype(np.float64)
        phases.append(CommPhase.build(machine, src, dst, size))
    return machine, PhaseStack.build(phases)


def _time_backend(machine, stack, backend: str, reps: int):
    # a fresh params clone per call defeats the pricing cache: every timed
    # evaluation performs the full segmented reduction
    def run():
        p = dataclasses.replace(machine.params)
        return stack.cost_arrays(p, backend=backend)
    return _best_of(run, reps=reps)


def bench_stack_backends():
    from repro.kernels.comm_stack import have_jax

    rows = []
    for tag, total, reps in (("small", SMALL_MSGS, 5),
                             ("large", LARGE_MSGS, 2)):
        machine, stack = _arena(total)
        us_np, ref = _time_backend(machine, stack, "numpy", reps)
        us_auto, got = _time_backend(machine, stack, "auto", reps)
        for a, b in zip(got, ref):
            np.testing.assert_allclose(a, b, rtol=2e-4, atol=1e-12), \
                "auto backend drifted from numpy"
        rows.append((f"stack_backend_numpy_{tag}", us_np, 1.0))
        rows.append((f"stack_auto_{tag}", us_auto, us_np / us_auto))
        if tag == "large" and have_jax():
            us_jax, got = _time_backend(machine, stack, "jax", reps)
            for a, b in zip(got, ref):
                np.testing.assert_allclose(a, b, rtol=2e-4, atol=1e-12)
            rows.append(("stack_jax_large", us_jax, us_np / us_jax))
    if have_jax():
        rows.append(_bench_jax_vs_onehot())
    return rows


def _legacy_one_hot_reduce():
    """The retired kernel, preserved as the comparison reference: segment
    sums via a one-hot [n_values, n_seg] matmul — the O(n * n_seg) memory
    blow-up that forced PALLAS_ONE_HOT_LIMIT and the host reroute."""
    import jax
    import jax.numpy as jnp

    @jax.jit
    def one_hot_sum(vals, ids, hot):
        return hot.T @ vals

    def run(vals, ids, n_seg):
        hot = jax.nn.one_hot(ids, n_seg, dtype=jnp.float32)
        return np.asarray(one_hot_sum(vals, ids, hot))
    return run


def _bench_jax_vs_onehot():
    import jax.numpy as jnp

    from repro.kernels.comm_stack import segment_sum

    rng = np.random.default_rng(3)
    vals = np.abs(rng.standard_normal(ONEHOT_MSGS)).astype(np.float32) * 10
    ids = rng.integers(0, ONEHOT_SEGS, ONEHOT_MSGS)
    dvals = jnp.asarray(vals)
    dids = jnp.asarray(ids, dtype=jnp.int32)

    legacy = _legacy_one_hot_reduce()
    us_old, want = _best_of(lambda: legacy(dvals, dids, ONEHOT_SEGS), reps=3)
    us_new, got = _best_of(
        lambda: segment_sum(dvals, dids, ONEHOT_SEGS, backend="jax"), reps=3)
    np.testing.assert_allclose(got, want.astype(np.float64), rtol=1e-3,
                               atol=1e-3)
    return ("stack_jax_vs_onehot", us_new, us_old / us_new)


ALL_BENCHES = [bench_stack_backends]


def main(save_json: str | None = None) -> None:
    import json
    import platform

    print("name,us_per_call,derived")
    rows = bench_stack_backends()
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived:.6g}", flush=True)
    if save_json:
        from repro.kernels.comm_stack import _probe_tag, autotune_crossover
        payload = {
            "rows": [{"name": n, "us_per_call": round(us, 1),
                      "derived": round(d, 4)} for n, us, d in rows],
            "probe_tag": _probe_tag(),
            "autotune_crossover": autotune_crossover(),
            "python": platform.python_version(),
            "arena_msgs": {"small": SMALL_MSGS, "large": LARGE_MSGS},
        }
        with open(save_json, "w") as f:
            json.dump(payload, f, indent=1, default=str)
            f.write("\n")


if __name__ == "__main__":
    import sys
    main(sys.argv[1] if len(sys.argv) > 1 else None)
